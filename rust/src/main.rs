//! `dkm` — the launcher CLI for distributed kernel-machine training.
//!
//! Subcommands:
//!   train       Run Algorithm 1 on a dataset (synthetic spec or LibSVM file)
//!   stagewise   Stage-wise basis growth (§3) with per-stage accuracy
//!   linearized  Formulation-(3) baseline (Zhang et al.) with timing slices
//!   ppacksvm    P-packSVM baseline (Zhu et al.)
//!   serve       Closed-loop serving: micro-batching queue over a
//!               prediction-only session (load a saved model or train one)
//!   trace       record | inspect | replay a deterministic phase trace
//!   info        Show the artifact manifest the runtime would load
//!
//! `train` and `stagewise` drive one stateful `Session`: the cluster, the
//! C blocks and the prepared operands are built ONCE and reused for every
//! solve, growth stage, λ re-solve (`--lambda-sweep`) and prediction batch
//! — prediction is re-sharded over the live cluster and shows up as its
//! own metered `predict` step in both reports.
//!
//! Examples:
//!   dkm train --dataset covtype_like --m 800 --nodes 8 --backend pjrt
//!   dkm train --libsvm data/a9a --ntest 2000 --m 400 --sigma 2
//!   dkm train --dataset covtype_like --lambda-sweep 0.05,0.01,0.002
//!   dkm stagewise --dataset covtype_like --stages 100,400,1600
//!   dkm linearized --dataset vehicle_like --m 400
//!   dkm serve --model model.dkm --clients 16 --max-batch 64 --exec pool

use std::collections::BTreeMap;
use std::sync::Arc;

use dkm::baselines::{train_linearized, train_ppacksvm, PPackOptions};
use dkm::cluster::CostModel;
use dkm::config::{Args, Settings};
use dkm::coordinator::{growth_settings, Session, ServingSession, Solve, TrainedModel};
use dkm::serve::ServeConfig;
use dkm::data::{synth, Dataset};
use dkm::metrics::{Step, Table};
use dkm::runtime::{make_backend, Manifest};
use dkm::trace::Trace;
use dkm::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const TRAIN_FLAGS: &[&str] = &[
    "dataset", "libsvm", "ntest", "ntrain", "m", "nodes", "lambda", "sigma", "loss", "basis",
    "backend", "exec", "sched", "skew", "c-storage", "c-memory-budget", "eval-pipeline", "solver", "max-iters",
    "tol", "solver-max-iters", "solver-tol", "seed", "kmeans-iters", "artifacts", "config",
    "stages", "pack", "epochs", "verbose", "cost", "lambda-sweep", "save-model",
    // resilience flags
    "faults", "retries", "retry-backoff", "checkpoint-every", "checkpoint", "resume",
    "trace", "limit",
    // serve-only flags
    "model", "clients", "requests", "think-ms", "max-batch", "max-delay-ms", "slots",
    "queue-cap", "json",
];

fn run() -> Result<()> {
    let args = Args::from_env()?;
    args.validate(TRAIN_FLAGS)?;
    let cmd = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "stagewise" => cmd_stagewise(&args),
        "linearized" => cmd_linearized(&args),
        "ppacksvm" => cmd_ppacksvm(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "dkm — distributed nonlinear kernel machines (Nyström formulation (4) + AllReduce TRON)

USAGE: dkm <train|stagewise|linearized|ppacksvm|serve|trace|info> [--flags]

Common flags:
  --dataset NAME    vehicle_like | covtype_like | ccat_like | mnist8m_like
  --libsvm PATH     train from a LibSVM file instead of a synthetic spec
  --ntrain N / --ntest N   synthetic sizes (defaults from the Table-3 spec)
  --m M             number of basis points
  --nodes P         simulated cluster size
  --lambda/--sigma  hyper-parameters (defaults from the dataset spec)
  --loss            sqhinge | logistic | squared
  --basis           random | kmeans | auto
  --backend         pjrt | native
  --exec            serial | threads[:N] | pool[:N]   (execution layer:
                    metered serial loop, OS worker threads spawned per
                    phase, or a persistent worker pool parked across phases
                    — bit-identical results, :N caps the worker count)
  --sched           static | steal[:grain]   (phase scheduling: fixed
                    contiguous node chunks per worker, or a shared claim
                    cursor so idle workers steal remaining nodes —
                    bit-identical results; grain shapes only the simulated
                    makespan model, default 4)
  --skew            none | J=F[,J=F...] | rand:MAX[:SEED]   (simulated
                    fleet heterogeneity: per-node speed multipliers ≥ 1,
                    e.g. 0=4 makes node 0 four times slower on the ledger)
  --c-storage       materialized | streaming | streaming:rowbuf | auto
                    (C-block memory model: stored kernel rows, per-dispatch
                    recompute, recompute with a row-scoped tile scratch
                    that halves it for m > TM, or a budgeted mix —
                    bit-identical results)
  --c-memory-budget per-node byte budget for --c-storage auto (e.g. 256m)
  --eval-pipeline   fused | split   (evaluation pipeline for either solver:
                    one fused compute+reduce phase per evaluation — one
                    barrier, one AllReduce round-trip — or the paper's
                    literal compute + 2-reduce sequence; bit-identical
                    results)
  --solver          tron | bcd[:block]   (master-side solver: the paper's
                    trust-region Newton, or distributed block coordinate
                    descent updating `block` β coordinates per round with
                    O(block)-float communication — same substrate, same
                    ledger)
  --solver-max-iters / --solver-tol   outer-round cap and relative stopping
                    tolerance for whichever solver is selected
                    (--max-iters / --tol are aliases, kept for scripts)
  --cost            free | hadoop | mpi   (simulated comm cost model)
  --stages a,b,c    stage-wise m schedule (stagewise command)
  --lambda-sweep a,b,c   after the main solve, warm re-solve the SAME
                    session at each λ (C computed once; train command)
  --save-model PATH save the trained model (basis, β, γ, loss) for a
                    serving process; on `train` this is the main solve's
                    model (a later --lambda-sweep does not affect it), on
                    `stagewise` the final stage's model
  --config FILE     key=value settings file (CLI flags override)

Resilience flags (train/stagewise; every recovery is bit-identical):
  --faults SPEC     inject phase faults on the simulated cluster:
                    node=J@phase=K[,node=J@phase=K...] kills node J in
                    global phase K, rand:P:SEED kills a pseudo-random
                    node with probability P per phase (deterministic in
                    SEED); failed phases re-run under --retries
  --retries N       bounded per-phase retry budget (default 2); an
                    exhausted budget aborts the run with phase context
  --retry-backoff X simulated seconds charged to the ledger per retry
                    (scaled by attempt number; default 0.05)
  --checkpoint-every N   snapshot the solver state to --checkpoint every
                    N outer rounds (0 = off); a resumed run finishes
                    bitwise identical to an uninterrupted one
  --checkpoint PATH where the latest checkpoint lands (default dkm.ckpt)
  --resume PATH     continue a `train` run from a checkpoint written by
                    --checkpoint-every (same data/flags; --exec/--sched/
                    --skew may differ)
  --trace PATH      record every ledger-visible event from cluster birth,
                    verify it replays to the live ledger bitwise, and
                    save the manifest after the solve (train command; see
                    `dkm trace`)

Trace subcommands (dkm trace <record|inspect|replay>):
  dkm trace record OUT [train flags]   run a training session with the
                    recorder on, verify replay, save the manifest to OUT
  dkm trace inspect PATH [--limit N]   print the manifest header and the
                    first N records (default 40)
  dkm trace replay PATH                re-run the records against a fresh
                    simulated ledger and check it lands bitwise on the
                    recorded snapshot

Serve flags (dkm serve; every reply is checked bit-identical to the
serial scoring loop):
  --model PATH      serve a model saved with --save-model (default: train
                    one in-process first with the training flags above)
  --clients N       closed-loop client threads (default 8)
  --requests N      total requests, split across clients (default 512)
  --think-ms X      mean exponential client think time ⇒ Poisson-ish
                    arrivals (default 1.0; 0 = hammer)
  --max-batch N     flush the queue at this many waiting rows (default 32)
  --max-delay-ms X  ...or when the oldest request is this old (default 2)
  --slots N         micro-batches per multi-slot dispatch: one flush
                    drains up to N·max-batch rows into ONE executor phase
                    sharing ONE barrier (default 4)
  --queue-cap N     queue bound; full-queue submits block (default 1024)
  --json PATH       also write the serve report as JSON
";

fn settings_from(args: &Args) -> Result<Settings> {
    let mut s = match args.str_opt("config") {
        Some(path) => Settings::from_file(path)?,
        None => Settings::default(),
    };
    if let Some(name) = args.str_opt("dataset") {
        s = s.with_dataset_defaults(name);
    }
    let mut kv = BTreeMap::new();
    for (flag, key) in [
        ("m", "m"),
        ("nodes", "nodes"),
        ("lambda", "lambda"),
        ("sigma", "sigma"),
        ("loss", "loss"),
        ("basis", "basis"),
        ("backend", "backend"),
        ("exec", "executor"),
        ("sched", "sched"),
        ("skew", "skew"),
        ("c-storage", "c_storage"),
        ("c-memory-budget", "c_memory_budget"),
        ("eval-pipeline", "eval_pipeline"),
        ("solver", "solver"),
        ("max-iters", "max_iters"),
        ("tol", "tol"),
        ("solver-max-iters", "solver_max_iters"),
        ("solver-tol", "solver_tol"),
        ("seed", "seed"),
        ("kmeans-iters", "kmeans_iters"),
        ("artifacts", "artifacts_dir"),
        ("faults", "faults"),
        ("retries", "retries"),
        ("retry-backoff", "retry_backoff"),
        ("checkpoint-every", "checkpoint_every"),
        ("checkpoint", "checkpoint_path"),
    ] {
        if let Some(v) = args.str_opt(flag) {
            kv.insert(key.to_string(), v.to_string());
        }
    }
    s.apply(&kv)?;
    Ok(s)
}

fn cost_from(args: &Args) -> Result<CostModel> {
    Ok(match args.str_or("cost", "hadoop").as_str() {
        "free" => CostModel::free(),
        "hadoop" => CostModel::hadoop_crude(),
        "mpi" => CostModel::mpi(),
        other => anyhow::bail!("unknown cost model {other:?} (free|hadoop|mpi)"),
    })
}

fn load_data(args: &Args, s: &Settings) -> Result<(Dataset, Dataset)> {
    if let Some(path) = args.str_opt("libsvm") {
        let full = dkm::data::libsvm::read_file(path, 0)?;
        let ntest = args.usize_or("ntest", full.n() / 5)?;
        let mut rng = dkm::rng::Rng::new(s.seed);
        Ok(full.split(ntest, &mut rng))
    } else {
        let mut spec = synth::spec(&s.dataset);
        spec.n_train = args.usize_or("ntrain", spec.n_train)?;
        spec.n_test = args.usize_or("ntest", spec.n_test)?;
        Ok(synth::generate(&spec, s.seed))
    }
}

fn parse_f32_list(spec: &str, flag: &str) -> Result<Vec<f32>> {
    spec.split(',')
        .map(|t| {
            t.trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("{flag}: {e}"))
        })
        .collect()
}

/// Session-state report: the cumulative wall clock and simulated ledger
/// (INCLUDING the metered predict step) plus the last solve's statistics.
fn print_run_report(session: &Session, solve: &Solve, acc: f64, verbose: bool) {
    println!("\n== Algorithm-1 wall clock (host) ==");
    let mut t = Table::new(&["step", "seconds"]);
    for step in Step::all() {
        let secs = session.wall().wall_secs(step);
        if secs > 0.0 {
            t.row(&[step.name().into(), format!("{secs:.3}")]);
        }
    }
    print!("{}", t.render());
    println!("\n== Simulated p-node ledger (compute max/node + C+D·B comm) ==");
    print!("{}", session.sim().report());
    println!(
        "solver {}: {} rounds, {} f/g evals, {} Hd evals, final f {:.6e}, |g| {:.3e}",
        solve.stats.solver,
        solve.stats.iterations,
        solve.fg_evals,
        solve.hd_evals,
        solve.stats.final_f,
        solve.stats.final_gnorm
    );
    println!(
        "comm: {} barriers, {} AllReduce round-trips, {} tree-level instances, {} bytes",
        session.sim().barriers(),
        session.sim().comm_rounds(),
        session.sim().comm_instances(),
        session.sim().comm_bytes(),
    );
    let sim = session.sim();
    if sim.sum_node_secs() > 0.0 {
        println!(
            "stragglers: slowest-node bound {:.3}s over {:.3}s total node work (ratio {:.2}× at p={})",
            sim.max_node_secs(),
            sim.sum_node_secs(),
            sim.straggler_ratio(session.p()),
            session.p(),
        );
    }
    println!(
        "c-storage: peak {:.2} MiB of C per node (+ {:.2} MiB W-row cache), {} kernel-tile recomputes",
        solve.peak_c_bytes as f64 / (1 << 20) as f64,
        solve.peak_w_cache_bytes as f64 / (1 << 20) as f64,
        solve.recomputed_tiles
    );
    if verbose {
        println!("loss curve: {:?}", solve.stats.f_curve());
    }
    println!("test accuracy: {acc:.4}");
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut s = settings_from(args)?;
    let trace_path = args.str_opt("trace");
    s.trace = trace_path.is_some();
    let resume_path = args.str_opt("resume");
    let cost = cost_from(args)?;
    let (train_ds, test_ds) = load_data(args, &s)?;
    println!(
        "dataset {} n={} d={} ntest={} | m={} p={} λ={} σ={} loss={} backend={:?} exec={} sched={} skew={} c-storage={} eval-pipeline={}",
        train_ds.name,
        train_ds.n(),
        train_ds.d(),
        test_ds.n(),
        s.m,
        s.nodes,
        s.lambda,
        s.sigma,
        s.loss.name(),
        s.backend,
        s.executor.name(),
        s.sched.name(),
        s.skew.name(),
        s.c_storage.name(),
        s.eval_pipeline.name(),
    );
    let backend = make_backend(s.backend, &s.artifacts_dir)?;
    let mut session = match resume_path {
        Some(ck) => {
            println!("resuming from checkpoint {ck}");
            Session::resume_from(&s, &train_ds, Arc::clone(&backend), cost, ck)?
        }
        None => Session::build(&s, &train_ds, Arc::clone(&backend), cost)?,
    };
    let solve = session.solve()?;
    // The training trace closes here: prediction below meters a side
    // ledger, a λ sweep would be a second solve on the same clock.
    if let Some(path) = trace_path {
        let trace = session
            .take_trace()
            .ok_or_else(|| anyhow::anyhow!("--trace was set but no trace was recorded"))?;
        trace.replay_verified()?;
        trace.save(path)?;
        println!(
            "trace saved to {path}: {} records over p={}, replay verified bitwise",
            trace.records.len(),
            trace.p
        );
    }
    // Scoring goes through the session: distributed over the live cluster,
    // metered as the `predict` step in both reports below.
    let acc = session.accuracy(&test_ds)?;
    print_run_report(&session, &solve, acc, args.bool("verbose"));

    // Snapshot the reported main-solve model BEFORE any sweep mutates the
    // session, so --save-model ships exactly the model reported above.
    if let Some(path) = args.str_opt("save-model") {
        session.model().save(path)?;
        println!("model saved to {path} (λ={})", session.lambda());
    }

    if let Some(spec) = args.str_opt("lambda-sweep") {
        let lambdas = parse_f32_list(spec, "--lambda-sweep")?;
        println!(
            "\n== λ sweep: warm re-solves on the live session (C computed once, β warm-started) =="
        );
        let mut t = Table::new(&[
            "lambda", "iters", "fg_evals", "final_f", "accuracy", "solve_secs",
        ]);
        for lam in lambdas {
            session.set_lambda(lam)?;
            let sv = session.solve()?;
            let acc = session.accuracy(&test_ds)?;
            t.row(&[
                format!("{lam}"),
                sv.stats.iterations.to_string(),
                sv.fg_evals.to_string(),
                format!("{:.6e}", sv.stats.final_f),
                format!("{acc:.4}"),
                format!("{:.3}", sv.solve_wall_secs),
            ]);
        }
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_stagewise(args: &Args) -> Result<()> {
    anyhow::ensure!(
        args.str_opt("lambda-sweep").is_none(),
        "--lambda-sweep is a `train` flag; on `stagewise` each stage already \
         re-solves the live session (run `dkm train --lambda-sweep ...` instead)"
    );
    let s = settings_from(args)?;
    let cost = cost_from(args)?;
    let stages: Vec<usize> = args
        .str_or("stages", "100,200,400")
        .split(',')
        .map(|t| t.trim().parse().map_err(|e| anyhow::anyhow!("--stages: {e}")))
        .collect::<Result<_>>()?;
    let (train_ds, test_ds) = load_data(args, &s)?;
    let backend = make_backend(s.backend, &s.artifacts_dir)?;
    // One session for the whole schedule: grow + warm re-solve in place.
    let staged = growth_settings(&s, &stages)?;
    let mut session = Session::build(&staged, &train_ds, Arc::clone(&backend), cost)?;
    let mut t = Table::new(&["m", "accuracy", "iters", "fg_evals", "solve_secs"]);
    for (i, &m) in stages.iter().enumerate() {
        if i > 0 {
            session.grow_basis(m)?;
        }
        let solve = session.solve()?;
        let acc = session.accuracy(&test_ds)?;
        t.row(&[
            m.to_string(),
            format!("{acc:.4}"),
            solve.stats.iterations.to_string(),
            solve.fg_evals.to_string(),
            format!("{:.2}", solve.solve_wall_secs),
        ]);
    }
    print!("{}", t.render());
    println!("\n== session ledger (all stages + prediction) ==");
    print!("{}", session.sim().report());
    println!(
        "comm: {} barriers, {} AllReduce round-trips",
        session.sim().barriers(),
        session.sim().comm_rounds()
    );
    let sim = session.sim();
    if sim.sum_node_secs() > 0.0 {
        println!(
            "stragglers: slowest-node bound {:.3}s over {:.3}s total node work (ratio {:.2}× at p={})",
            sim.max_node_secs(),
            sim.sum_node_secs(),
            sim.straggler_ratio(session.p()),
            session.p(),
        );
    }
    if let Some(path) = args.str_opt("save-model") {
        session.model().save(path)?;
        println!("model saved to {path}");
    }
    Ok(())
}

fn cmd_linearized(args: &Args) -> Result<()> {
    let s = settings_from(args)?;
    let (train_ds, test_ds) = load_data(args, &s)?;
    let out = train_linearized(&s, &train_ds)?;
    println!(
        "formulation (3): m={} rank={} | kernel {:.2}s eig {:.2}s A {:.2}s tron {:.2}s total {:.2}s (A fraction {:.4})",
        s.m,
        out.rank,
        out.kernel_secs,
        out.eig_secs,
        out.a_secs,
        out.tron_secs,
        out.total_secs,
        out.a_fraction()
    );
    println!("test accuracy: {:.4}", out.accuracy(&test_ds));
    Ok(())
}

fn cmd_ppacksvm(args: &Args) -> Result<()> {
    let s = settings_from(args)?;
    let cost = cost_from(args)?;
    let (train_ds, test_ds) = load_data(args, &s)?;
    let opts = PPackOptions {
        pack: args.usize_or("pack", 100)?,
        epochs: args.usize_or("epochs", 1)?,
        lambda: s.lambda / train_ds.n() as f32, // Pegasos λ is per-example
        seed: s.seed,
        nodes: s.nodes,
    };
    let out = train_ppacksvm(&train_ds, s.gamma(), &opts, cost)?;
    let backend = make_backend(s.backend, &s.artifacts_dir)?;
    let acc = out.model.accuracy(backend.as_ref(), &test_ds)?;
    println!(
        "p-packsvm: rounds={} support={} wall {:.2}s sim {:.2}s (comm {:.2}s)",
        out.rounds,
        out.n_support,
        out.wall_secs,
        out.sim.total_secs(),
        out.sim.comm_secs(Step::Tron),
    );
    println!("test accuracy: {acc:.4}");
    Ok(())
}

fn f64_or(args: &Args, key: &str, default: f64) -> Result<f64> {
    match args.str_opt(key) {
        None => Ok(default),
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let s = settings_from(args)?;
    let cost = cost_from(args)?;
    let (train_ds, test_ds) = load_data(args, &s)?;
    let backend = make_backend(s.backend, &s.artifacts_dir)?;
    let model = match args.str_opt("model") {
        Some(path) => {
            let m = TrainedModel::load(path)?;
            println!(
                "loaded model from {path}: m={} d={}",
                m.beta.len(),
                m.basis.cols()
            );
            m
        }
        None => {
            println!(
                "no --model given: training one in-process first (m={} p={})",
                s.m, s.nodes
            );
            dkm::coordinator::train(&s, &train_ds, Arc::clone(&backend), cost)?.model
        }
    };
    // Serial reference scores for the whole request pool (the test set):
    // every served reply is checked bit-identical against these.
    let expected = model.predict(backend.as_ref(), &test_ds.x)?;
    let session = ServingSession::load(
        &model,
        Arc::clone(&backend),
        s.nodes,
        s.executor.to_executor(),
        cost,
    )?
    .with_sched(s.sched)
    .with_skew(s.skew.clone());
    let clients = args.usize_or("clients", 8)?;
    let requests = args.usize_or("requests", 512)?;
    anyhow::ensure!(clients >= 1, "--clients must be >= 1");
    let cfg = ServeConfig {
        clients,
        requests_per_client: requests.div_ceil(clients),
        mean_think_ms: f64_or(args, "think-ms", 1.0)?,
        max_batch: args.usize_or("max-batch", 32)?,
        max_delay_ms: f64_or(args, "max-delay-ms", 2.0)?,
        slots: args.usize_or("slots", 4)?,
        queue_cap: args.usize_or("queue-cap", 1024)?,
        seed: s.seed,
    };
    println!(
        "serving m={} over p={} ({}): {} clients × {} requests, flush at {} rows or {}ms, ≤{} micro-batches/dispatch",
        session.m(),
        session.p(),
        s.executor.name(),
        cfg.clients,
        cfg.requests_per_client,
        cfg.max_batch,
        cfg.max_delay_ms,
        cfg.slots,
    );
    let report = dkm::serve::run(&session, &test_ds.x, Some(&expected), &cfg)?;
    print!("{}", report.render());
    println!("\n== simulated serving ledger ==");
    print!("{}", session.sim().report());
    let sim = session.sim();
    if sim.sum_node_secs() > 0.0 {
        println!(
            "stragglers: slowest-node bound {:.3}s over {:.3}s total node work (ratio {:.2}× at p={})",
            sim.max_node_secs(),
            sim.sum_node_secs(),
            sim.straggler_ratio(session.p()),
            session.p(),
        );
    }
    anyhow::ensure!(
        report.mismatches == 0,
        "{} replies diverged from the serial reference",
        report.mismatches
    );
    if let Some(path) = args.str_opt("json") {
        std::fs::write(path, format!("{}\n", report.to_json()))?;
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let sub = args
        .positional()
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("help");
    match sub {
        "record" => {
            let out = args
                .positional()
                .get(2)
                .cloned()
                .unwrap_or_else(|| "dkm.trace".to_string());
            let mut s = settings_from(args)?;
            s.trace = true;
            let cost = cost_from(args)?;
            let (train_ds, _) = load_data(args, &s)?;
            let backend = make_backend(s.backend, &s.artifacts_dir)?;
            let mut session = Session::build(&s, &train_ds, Arc::clone(&backend), cost)?;
            let solve = session.solve()?;
            let trace = session
                .take_trace()
                .ok_or_else(|| anyhow::anyhow!("tracing was enabled but produced no trace"))?;
            // Prove the manifest is sound before shipping it: replay must
            // land on the live ledger bit-for-bit.
            trace.replay_verified()?;
            trace.save(&out)?;
            println!(
                "trace saved to {out}: {} records over p={} (solver {}, {} rounds), replay verified bitwise",
                trace.records.len(),
                trace.p,
                solve.stats.solver,
                solve.stats.iterations
            );
            Ok(())
        }
        "inspect" => {
            let path = path_arg(args, "inspect")?;
            let trace = Trace::load(&path)?;
            print!("{}", trace.render(args.usize_or("limit", 40)?));
            Ok(())
        }
        "replay" => {
            let path = path_arg(args, "replay")?;
            let trace = Trace::load(&path)?;
            let clock = trace.replay_verified()?;
            println!("== replayed ledger ==");
            print!("{}", clock.report());
            println!(
                "replay OK: {} records reproduced the recorded ledger bitwise \
                 ({} barriers, {} AllReduce round-trips, {} faults, {} retries)",
                trace.records.len(),
                clock.barriers(),
                clock.comm_rounds(),
                clock.faults(),
                clock.retries(),
            );
            Ok(())
        }
        other => anyhow::bail!(
            "unknown trace subcommand {other:?}: dkm trace <record|inspect|replay> \
             (record OUT [train flags] | inspect PATH [--limit N] | replay PATH)"
        ),
    }
}

/// The PATH positional of `dkm trace inspect|replay`.
fn path_arg(args: &Args, sub: &str) -> Result<String> {
    args.positional()
        .get(2)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("dkm trace {sub} PATH: missing trace path"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let m = Manifest::load(&dir)?;
    println!(
        "artifacts at {dir}: TB={} TM={} widths={:?} losses={:?}",
        m.tb, m.tm, m.ds, m.losses
    );
    let mut t = Table::new(&["module", "inputs", "outputs"]);
    for module in &m.modules {
        t.row(&[
            module.name.clone(),
            module
                .inputs
                .iter()
                .map(|i| format!("{:?}", i.shape))
                .collect::<Vec<_>>()
                .join(" "),
            module
                .outputs
                .iter()
                .map(|o| format!("{:?}", o.shape))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
