//! Datasets: synthetic benchmark generators, LibSVM text IO, sharding.
//!
//! The paper evaluates on Vehicle, Covtype, CCAT and MNIST8m (Table 3).
//! None are redistributable here (repro gate), so [`synth`] provides
//! generators with matched *shape*: same feature dimensionality and
//! character, and ground-truth boundaries tuned so the paper's observable
//! trends (accuracy-vs-m climb rate, kernel-compute vs TRON cost balance)
//! reproduce. See DESIGN.md §2 for the substitution argument.

pub mod dataset;
pub mod libsvm;
pub mod synth;

pub use dataset::{shard_rows, Dataset, DatasetSpec};
