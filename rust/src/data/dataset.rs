//! Core dataset container + row sharding (step 1 of Algorithm 1).

use crate::linalg::Mat;
use crate::rng::Rng;

/// A binary classification dataset: row-major features, labels in {-1, +1}.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Mat,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Mat, y: Vec<f32>) -> Self {
        assert_eq!(x.rows(), y.len(), "feature/label count mismatch");
        assert!(
            y.iter().all(|&v| v == 1.0 || v == -1.0),
            "labels must be +/-1"
        );
        Self {
            name: name.into(),
            x,
            y,
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Fraction of positive labels.
    pub fn pos_fraction(&self) -> f32 {
        self.y.iter().filter(|&&v| v > 0.0).count() as f32 / self.n() as f32
    }

    /// Random permutation split into (train, test).
    pub fn split(&self, n_test: usize, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!(n_test < self.n());
        let mut idx: Vec<usize> = (0..self.n()).collect();
        rng.shuffle(&mut idx);
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.subset(train_idx), self.subset(test_idx))
    }

    /// Row subset (copying).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            name: self.name.clone(),
            x: self.x.gather_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }
}

/// Everything a benchmark needs to instantiate a dataset: the generator
/// handle plus the paper's hyper-parameters for it (Table 3).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub n_train: usize,
    pub n_test: usize,
    pub d: usize,
    /// Regularization constant λ of formulation (4).
    pub lambda: f32,
    /// Gaussian kernel width σ; gamma = 1 / (2 σ²).
    pub sigma: f32,
}

impl DatasetSpec {
    pub fn gamma(&self) -> f32 {
        1.0 / (2.0 * self.sigma * self.sigma)
    }
}

/// Step 1 of Algorithm 1: row ranges for p nodes (contiguous blocks after
/// the caller's shuffle; block j gets the remainder spread evenly).
pub fn shard_rows(n: usize, p: usize) -> Vec<std::ops::Range<usize>> {
    assert!(p > 0);
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for j in 0..p {
        let len = base + usize::from(j < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = Mat::from_vec(4, 2, vec![0., 0., 1., 0., 0., 1., 1., 1.]);
        Dataset::new("tiny", x, vec![1.0, -1.0, -1.0, 1.0])
    }

    #[test]
    fn split_partitions_rows() {
        let d = tiny();
        let mut rng = Rng::new(1);
        let (tr, te) = d.split(1, &mut rng);
        assert_eq!(tr.n(), 3);
        assert_eq!(te.n(), 1);
    }

    #[test]
    fn shard_rows_covers_everything() {
        for (n, p) in [(10, 3), (7, 7), (100, 1), (5, 8)] {
            let shards = shard_rows(n, p);
            assert_eq!(shards.len(), p);
            let total: usize = shards.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} p={p}");
            // contiguous and ordered
            let mut next = 0;
            for r in &shards {
                assert_eq!(r.start, next);
                next = r.end;
            }
            // balanced within 1
            let lens: Vec<usize> = shards.iter().map(|r| r.len()).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    #[should_panic(expected = "labels must be +/-1")]
    fn rejects_bad_labels() {
        let x = Mat::zeros(1, 1);
        Dataset::new("bad", x, vec![0.5]);
    }
}
