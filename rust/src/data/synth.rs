//! Synthetic benchmark generators matched to the paper's datasets (Table 3).
//!
//! Each generator draws from an **RBF teacher**: a ground-truth classifier
//! `sign(Σ_t w_t exp(-γ_t ||x - c_t||²) + b)` with the number of teacher
//! centers controlling boundary complexity. This matters for fidelity:
//!
//! * `covtype_like` uses *many* centers + label noise → the learned machine
//!   needs many basis points (the paper: "for Covtype the number of support
//!   vectors is more than half the training set; the curve does not
//!   stabilize even at m = 51200"). Accuracy-vs-m climbs slowly — Fig 1
//!   left.
//! * `ccat_like` uses few centers on sparse-ish high-d data → accuracy
//!   saturates at small m — Fig 1 right.
//! * `mnist8m_like` uses well-separated class clusters → very high
//!   achievable accuracy (paper Table 5: 0.996), kernel computation (d=784)
//!   dominates cost — Table 4 / Fig 2 right.
//! * `vehicle_like` is the small dense workhorse for Table 1.
//!
//! Scale note: n is ~10-100x the paper's (one CPU core here); every bench
//! prints both the paper's n and ours (EXPERIMENTS.md carries the mapping).

use super::dataset::{Dataset, DatasetSpec};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Ground-truth RBF teacher parameters.
struct Teacher {
    centers: Mat,
    weights: Vec<f32>,
    gamma: f32,
    bias: f32,
}

impl Teacher {
    fn new(n_centers: usize, d: usize, gamma: f32, spread: f32, rng: &mut Rng) -> Self {
        let centers = Mat::from_fn(n_centers, d, |_, _| spread * rng.normal_f32());
        let weights = (0..n_centers)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        Teacher {
            centers,
            weights,
            gamma,
            bias: 0.0,
        }
    }

    fn score(&self, x: &[f32]) -> f32 {
        let mut s = self.bias;
        for t in 0..self.centers.rows() {
            let c = self.centers.row(t);
            let mut d2 = 0.0f32;
            for (xi, ci) in x.iter().zip(c) {
                let diff = xi - ci;
                d2 += diff * diff;
            }
            s += self.weights[t] * (-self.gamma * d2).exp();
        }
        s
    }

    /// Calibrate bias so classes are roughly balanced on a probe sample.
    fn calibrate(&mut self, probe: &Mat) {
        let mut scores: Vec<f32> = (0..probe.rows()).map(|i| self.score(probe.row(i))).collect();
        scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
        self.bias = -scores[scores.len() / 2];
    }
}

/// Draw a dataset from an RBF teacher over N(0, I_d)-ish inputs.
///
/// `sparsity` < 1.0 zeroes that fraction of coordinates per row (CCAT-like
/// text features); `noise` flips that fraction of labels (irreducible error,
/// keeps the boundary support-vector-dense).
#[allow(clippy::too_many_arguments)]
fn rbf_teacher_dataset(
    name: &str,
    n: usize,
    d: usize,
    n_centers: usize,
    teacher_gamma: f32,
    input_spread: f32,
    sparsity: f32,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut teacher = Teacher::new(n_centers, d, teacher_gamma, input_spread, &mut rng);

    let keep = 1.0 - sparsity;
    let mut x = Mat::from_fn(n, d, |_, _| 0.0);
    for i in 0..n {
        // Sample inputs near teacher centers half the time so the score
        // distribution has mass on both sides of the boundary.
        let near = rng.f32() < 0.5 && n_centers > 0;
        let center = if near {
            Some(teacher.centers.row(rng.below(n_centers)).to_vec())
        } else {
            None
        };
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            if sparsity > 0.0 && rng.f32() >= keep {
                *v = 0.0;
            } else {
                let base = center.as_ref().map_or(0.0, |c| c[j]);
                *v = base + input_spread * 0.6 * rng.normal_f32();
            }
        }
    }

    // Calibrate bias on the first 512 rows, then label.
    let probe = x.gather_rows(&(0..n.min(512)).collect::<Vec<_>>());
    teacher.calibrate(&probe);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let mut label = if teacher.score(x.row(i)) >= 0.0 { 1.0 } else { -1.0 };
        if noise > 0.0 && rng.f32() < noise {
            label = -label;
        }
        y.push(label);
    }
    Dataset::new(name, x, y)
}

/// Vehicle-like: small dense d=100 (paper: n=78,823, λ=8, σ=2).
pub fn vehicle_like(n: usize, seed: u64) -> Dataset {
    rbf_teacher_dataset("vehicle_like", n, 100, 24, 0.02, 2.0, 0.0, 0.01, seed)
}

/// Covtype-like: d=54, support-vector-dense boundary + label noise
/// (paper: n=522,910, λ=0.005, σ=0.09 — an extremely narrow kernel,
/// i.e. a very local, complex boundary).
pub fn covtype_like(n: usize, seed: u64) -> Dataset {
    rbf_teacher_dataset("covtype_like", n, 54, 160, 0.45, 1.0, 0.0, 0.02, seed)
}

/// CCAT-like: sparse high-d text-like features with a *nearly linear*
/// ground truth (RCV1/CCAT is close to linearly separable), so a kernel
/// machine saturates at small m — the Fig-1-right character.
/// (paper: n=781,265, d=47,236 sparse text; we keep the sparse character
/// at d=512 — DESIGN.md §2 documents the width reduction.)
pub fn ccat_like(n: usize, seed: u64) -> Dataset {
    let d = 512;
    let mut rng = Rng::new(seed);
    // A small informative "topic" sub-vocabulary (like CCAT's category
    // cues): 16 strong dims; the rest is sparse background vocabulary.
    let n_topic = 16;
    let w: Vec<f32> = (0..d)
        .map(|j| if j < n_topic { rng.normal_f32() * 2.0 } else { 0.0 })
        .collect();
    let mut x = Mat::zeros(n, d);
    let mut scores = Vec::with_capacity(n);
    for i in 0..n {
        let row = x.row_mut(i);
        let mut score = 0.0f32;
        for (j, v) in row.iter_mut().enumerate() {
            // Topic cues appear in half the documents; background terms in
            // ~6% — tf-idf-ish positive magnitudes either way.
            let p = if j < n_topic { 0.5 } else { 0.06 };
            if rng.f32() < p {
                *v = rng.f32() + 0.2;
                score += w[j] * *v;
            }
        }
        scores.push(score);
    }
    // Median-calibrated threshold keeps the classes balanced regardless of
    // the drawn topic weights (documents have positive-only magnitudes).
    let mut sorted = scores.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let bias = sorted[n / 2];
    let mut y = Vec::with_capacity(n);
    for &score in &scores {
        let mut label = if score >= bias { 1.0 } else { -1.0 };
        // Small irreducible error; the boundary itself is (near) linear,
        // which is what lets a modest basis saturate the curve early.
        if rng.f32() < 0.015 {
            label = -label;
        }
        y.push(label);
    }
    Dataset::new("ccat_like", x, y)
}

/// MNIST8m-like: d=784 dense image-like clusters, 2 classes of 5 clusters
/// each, very high achievable accuracy (paper Table 5: 0.9963).
pub fn mnist8m_like(n: usize, seed: u64) -> Dataset {
    let d = 784;
    let k = 10;
    let mut rng = Rng::new(seed);
    let centers = Mat::from_fn(k, d, |_, _| 1.2 * rng.normal_f32());
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(k);
        let row = x.row_mut(i);
        let center = centers.row(c);
        for (v, &cj) in row.iter_mut().zip(center) {
            *v = cj + 0.55 * rng.normal_f32();
        }
        y.push(if c % 2 == 0 { 1.0 } else { -1.0 });
    }
    // Overwrite the borrow (x moved via the builder above).
    Dataset::new("mnist8m_like", x, y)
}

/// The paper's Table 3, scaled for one core. λ/σ re-tuned for the synthetic
/// twins (the paper's σ values are tied to its datasets' feature scales).
pub fn spec(name: &str) -> DatasetSpec {
    match name {
        "vehicle_like" => DatasetSpec {
            name: "vehicle_like",
            n_train: 6_000,
            n_test: 1_500,
            d: 100,
            lambda: 8.0,
            sigma: 2.0,
        },
        "covtype_like" => DatasetSpec {
            name: "covtype_like",
            n_train: 24_000,
            n_test: 6_000,
            d: 54,
            lambda: 0.005,
            sigma: 2.0,
        },
        "ccat_like" => DatasetSpec {
            name: "ccat_like",
            n_train: 16_000,
            n_test: 4_000,
            d: 512,
            lambda: 0.1,
            sigma: 6.0,
        },
        "mnist8m_like" => DatasetSpec {
            name: "mnist8m_like",
            n_train: 32_000,
            n_test: 4_000,
            d: 784,
            lambda: 8.0,
            sigma: 18.0,
        },
        other => panic!("unknown dataset spec: {other}"),
    }
}

/// Generate train+test for a spec (test rows drawn from the same process).
pub fn generate(spec: &DatasetSpec, seed: u64) -> (Dataset, Dataset) {
    let total = spec.n_train + spec.n_test;
    let full = match spec.name {
        "vehicle_like" => vehicle_like(total, seed),
        "covtype_like" => covtype_like(total, seed),
        "ccat_like" => ccat_like(total, seed),
        "mnist8m_like" => mnist8m_like(total, seed),
        other => panic!("unknown dataset: {other}"),
    };
    let mut rng = Rng::new(seed ^ 0x5EED);
    let (train, test) = full.split(spec.n_test, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        let a = covtype_like(200, 7);
        let b = covtype_like(200, 7);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn generators_differ_across_seeds() {
        let a = covtype_like(100, 1);
        let b = covtype_like(100, 2);
        assert_ne!(a.x.as_slice(), b.x.as_slice());
    }

    #[test]
    fn classes_roughly_balanced() {
        for ds in [
            vehicle_like(2000, 3),
            covtype_like(2000, 3),
            ccat_like(2000, 3),
            mnist8m_like(2000, 3),
        ] {
            let f = ds.pos_fraction();
            assert!(
                (0.25..=0.75).contains(&f),
                "{}: pos fraction {f}",
                ds.name
            );
        }
    }

    #[test]
    fn ccat_like_is_sparse() {
        let ds = ccat_like(200, 5);
        let nz = ds.x.as_slice().iter().filter(|&&v| v != 0.0).count();
        let frac = nz as f32 / ds.x.as_slice().len() as f32;
        assert!(frac < 0.2, "nonzero fraction {frac}");
    }

    #[test]
    fn dims_match_paper_shape() {
        assert_eq!(vehicle_like(10, 1).d(), 100);
        assert_eq!(covtype_like(10, 1).d(), 54);
        assert_eq!(mnist8m_like(10, 1).d(), 784);
    }

    #[test]
    fn spec_generate_roundtrip() {
        let mut sp = spec("vehicle_like");
        sp.n_train = 300;
        sp.n_test = 100;
        let (tr, te) = generate(&sp, 11);
        assert_eq!(tr.n(), 300);
        assert_eq!(te.n(), 100);
        assert_eq!(tr.d(), sp.d);
    }
}
