//! LibSVM-format text IO, so users can run DKM on the paper's real datasets
//! (Vehicle / Covtype / CCAT / MNIST8m are all distributed in this format).
//!
//! Format: one example per line, `label idx:val idx:val ...`, 1-based
//! indices. Labels are mapped to {-1, +1} (0/1 inputs are accepted).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::linalg::Mat;
use crate::Result;

use super::dataset::Dataset;

/// Parse LibSVM text. `d` pads/truncates to a fixed width; pass 0 to infer
/// the max index seen.
pub fn parse(reader: impl BufRead, d: usize, name: &str) -> Result<Dataset> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?;
        let raw: f32 = label_tok
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad label {label_tok:?}: {e}", lineno + 1))?;
        let label = if raw > 0.0 { 1.0 } else { -1.0 };
        let mut feats = Vec::new();
        for tok in parts {
            let (i_str, v_str) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad feature {tok:?}", lineno + 1))?;
            let idx: usize = i_str
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad index {i_str:?}: {e}", lineno + 1))?;
            if idx == 0 {
                anyhow::bail!("line {}: LibSVM indices are 1-based", lineno + 1);
            }
            let val: f32 = v_str
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad value {v_str:?}: {e}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push(feats);
        labels.push(label);
    }
    let width = if d == 0 { max_idx } else { d };
    let mut x = Mat::zeros(rows.len(), width);
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            if j < width {
                *x.at_mut(i, j) = v;
            }
        }
    }
    Ok(Dataset::new(name, x, labels))
}

pub fn read_file(path: impl AsRef<Path>, d: usize) -> Result<Dataset> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    parse(std::io::BufReader::new(f), d, &name)
}

/// Write a dataset in LibSVM format (zeros skipped).
pub fn write_file(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.n() {
        write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_lines() {
        let text = "+1 1:0.5 3:2.0\n-1 2:1.0\n";
        let ds = parse(Cursor::new(text), 0, "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.x.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(ds.x.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn maps_01_labels() {
        let ds = parse(Cursor::new("0 1:1\n1 1:2\n"), 0, "t").unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let ds = parse(Cursor::new("# hi\n\n+1 1:1\n"), 0, "t").unwrap();
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse(Cursor::new("+1 0:1\n"), 0, "t").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(Cursor::new("abc 1:1\n"), 0, "t").is_err());
        assert!(parse(Cursor::new("+1 1:x\n"), 0, "t").is_err());
        assert!(parse(Cursor::new("+1 1\n"), 0, "t").is_err());
    }

    #[test]
    fn fixed_width_pads_and_truncates() {
        let ds = parse(Cursor::new("+1 5:1.0\n"), 3, "t").unwrap();
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.x.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn roundtrip_through_file() {
        let ds = crate::data::synth::vehicle_like(20, 3);
        let dir = std::env::temp_dir().join("dkm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, ds.d()).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.y, ds.y);
        for i in 0..ds.n() {
            for j in 0..ds.d() {
                assert!((back.x.at(i, j) - ds.x.at(i, j)).abs() < 1e-4);
            }
        }
        std::fs::remove_file(path).ok();
    }
}
