//! The `dkm serve` loop: a bounded request queue with adaptive
//! micro-batching in front of a [`ServingSession`].
//!
//! Shape of the system (all in-process, like the cluster sim):
//!
//! ```text
//! N closed-loop clients ──submit──▶ bounded queue ──▶ dispatcher
//!   (exponential think               (blocks when       flush on max-batch
//!    time ⇒ Poisson-ish               full: back-        OR max-delay, drain
//!    arrivals)                        pressure)          up to slots·max_batch,
//!                                                        ONE predict_many)
//! ```
//!
//! The dispatcher is where the two serving knobs meet: it flushes as soon
//! as `max_batch` requests are waiting (throughput) or the OLDEST waiting
//! request reaches `max_delay` (latency floor), and each flush drains up
//! to `slots` micro-batches into a single multi-slot
//! [`ServingSession::predict_many`] dispatch — so a traffic burst rides
//! one barrier instead of `slots`. Every reply is checked bit-identical
//! against the serial reference when one is supplied.
//!
//! [`run`] drives the whole loop and returns a [`ServeReport`]: qps and
//! latency percentiles on the WALL clock, plus the simulated ledger's
//! view of the same window (Step::Predict seconds, barriers/batch, comm
//! volume) — the two stories the ROADMAP's serving item asks for.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::Json;
use crate::coordinator::ServingSession;
use crate::linalg::Mat;
use crate::metrics::Step;
use crate::rng::Rng;
use crate::Result;

/// Knobs of one serving run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Closed-loop client threads.
    pub clients: usize,
    /// Requests each client issues before exiting.
    pub requests_per_client: usize,
    /// Mean exponential think time between a client's requests (0 = none;
    /// independent exponential thinkers ≈ Poisson arrivals at the queue).
    pub mean_think_ms: f64,
    /// Flush as soon as this many requests are waiting…
    pub max_batch: usize,
    /// …or as soon as the oldest waiting request is this old.
    pub max_delay_ms: f64,
    /// Micro-batches per dispatch: one flush drains up to
    /// `slots · max_batch` requests into one multi-slot phase.
    pub slots: usize,
    /// Queue bound; full-queue submits block (closed-loop backpressure).
    pub queue_cap: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            clients: 8,
            requests_per_client: 64,
            mean_think_ms: 1.0,
            max_batch: 32,
            max_delay_ms: 2.0,
            slots: 4,
            queue_cap: 1024,
            seed: 42,
        }
    }
}

/// One in-flight request: a row of the feature pool plus the reply pipe.
struct Request {
    row: usize,
    enqueued: Instant,
    reply: mpsc::Sender<f32>,
}

/// Should the dispatcher flush now? Pure so the policy is unit-testable:
/// flush on a full batch, or on ANY waiting work once the oldest request
/// has aged past the delay bound (or the queue is closing and this is the
/// drain).
fn flush_due(len: usize, oldest_age: Duration, max_batch: usize, max_delay: Duration, closed: bool) -> bool {
    len >= max_batch || (len > 0 && (closed || oldest_age >= max_delay))
}

/// Split a drained wave of `n` requests into micro-batch sizes of at most
/// `max_batch` (full batches first, remainder last).
fn plan_micro_batches(n: usize, max_batch: usize) -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut left = n;
    while left > 0 {
        let take = left.min(max_batch);
        sizes.push(take);
        left -= take;
    }
    sizes
}

struct QueueState {
    deque: VecDeque<Request>,
    closed: bool,
}

/// Bounded MPSC request queue: submits block while full (the closed-loop
/// clients ARE the backpressure), the dispatcher blocks until a flush is
/// due.
struct RequestQueue {
    state: Mutex<QueueState>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl RequestQueue {
    fn new(cap: usize) -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState {
                deque: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    fn submit(&self, req: Request) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        while st.deque.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        anyhow::ensure!(!st.closed, "request queue is closed");
        st.deque.push_back(req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Stop accepting new requests; queued ones still drain.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Close AND drop everything queued (replies error out) — the unwind
    /// path when a dispatch fails, so no client blocks forever.
    fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        st.deque.clear();
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Block until a flush is due ([`flush_due`]), then drain up to
    /// `max_wave` requests. An empty return means closed-and-drained.
    fn next_wave(&self, max_batch: usize, max_delay: Duration, max_wave: usize) -> Vec<Request> {
        let mut st = self.state.lock().unwrap();
        loop {
            let len = st.deque.len();
            let oldest = st.deque.front().map(|r| r.enqueued.elapsed());
            match oldest {
                Some(age) if flush_due(len, age, max_batch, max_delay, st.closed) => break,
                Some(age) => {
                    let left = max_delay.saturating_sub(age);
                    st = self.not_empty.wait_timeout(st, left).unwrap().0;
                }
                None if st.closed => return Vec::new(),
                None => st = self.not_empty.wait(st).unwrap(),
            }
        }
        let take = st.deque.len().min(max_wave);
        let wave: Vec<Request> = st.deque.drain(..take).collect();
        self.not_full.notify_all();
        wave
    }
}

/// The dispatcher: drain due waves, pack them into ≤`max_batch`
/// micro-batches, score each wave in ONE multi-slot dispatch, reply.
/// Returns (micro-batches scored, rows scored).
fn dispatch_loop(
    session: &ServingSession,
    pool: &Mat,
    queue: &RequestQueue,
    cfg: &ServeConfig,
) -> Result<(u64, u64)> {
    let max_delay = Duration::from_secs_f64(cfg.max_delay_ms / 1000.0);
    let max_wave = cfg.slots.max(1) * cfg.max_batch;
    let mut batches = 0u64;
    let mut rows = 0u64;
    loop {
        let wave = queue.next_wave(cfg.max_batch, max_delay, max_wave);
        if wave.is_empty() {
            return Ok((batches, rows));
        }
        let sizes = plan_micro_batches(wave.len(), cfg.max_batch);
        let mut mats = Vec::with_capacity(sizes.len());
        let mut at = 0usize;
        for &sz in &sizes {
            let mut data = Vec::with_capacity(sz * pool.cols());
            for req in &wave[at..at + sz] {
                data.extend_from_slice(pool.row_panel(req.row, req.row + 1));
            }
            mats.push(Mat::from_vec(sz, pool.cols(), data));
            at += sz;
        }
        let refs: Vec<&Mat> = mats.iter().collect();
        let scored = match session.predict_many(&refs) {
            Ok(s) => s,
            Err(e) => {
                queue.abort();
                return Err(e);
            }
        };
        let mut replies = wave.into_iter();
        for scores in scored {
            batches += 1;
            rows += scores.len() as u64;
            for score in scores {
                let req = replies.next().expect("one request per score");
                // A client that gave up is its own problem; drop the score.
                let _ = req.reply.send(score);
            }
        }
    }
}

/// One closed-loop client: think (exponential), pick a pool row, submit,
/// wait for the score, check it bit-identical to the reference. Returns
/// the observed submit→reply latencies in milliseconds.
fn client_loop(
    queue: &RequestQueue,
    cfg: &ServeConfig,
    mut rng: Rng,
    pool_rows: usize,
    expected: Option<&[f32]>,
    mismatches: &AtomicU64,
) -> Vec<f64> {
    let mut latencies = Vec::with_capacity(cfg.requests_per_client);
    for _ in 0..cfg.requests_per_client {
        if cfg.mean_think_ms > 0.0 {
            let think_ms = -cfg.mean_think_ms * (1.0 - rng.f64()).ln();
            std::thread::sleep(Duration::from_secs_f64(think_ms / 1000.0));
        }
        let row = rng.below(pool_rows);
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        let req = Request {
            row,
            enqueued: t0,
            reply: tx,
        };
        if queue.submit(req).is_err() {
            break; // aborted run
        }
        match rx.recv() {
            Ok(score) => {
                latencies.push(t0.elapsed().as_secs_f64() * 1000.0);
                if let Some(exp) = expected {
                    if score.to_bits() != exp[row].to_bits() {
                        mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(_) => break, // dispatcher died; run() surfaces its error
        }
    }
    latencies
}

/// What one [`run`] produced: throughput + latency on the wall clock, and
/// the same serving window on the simulated ledger.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Requests answered (== issued unless the run aborted).
    pub requests: u64,
    /// Micro-batches scored.
    pub batches: u64,
    pub mean_batch_rows: f64,
    pub wall_secs: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Replies that were not bit-identical to the serial reference
    /// (always 0 unless something is broken; only counted when a
    /// reference was supplied).
    pub mismatches: u64,
    /// Sim-ledger deltas over the run's window.
    pub barriers: u64,
    pub comm_instances: u64,
    pub comm_bytes: u64,
    pub sim_predict_secs: f64,
    /// Barriers ÷ micro-batches: < 1.0 whenever a flush carried more than
    /// one micro-batch through a single multi-slot dispatch.
    pub barriers_per_batch: f64,
    /// Most batches simultaneously in flight in any one dispatch.
    pub peak_slots_in_flight: u64,
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            o.insert(k.to_string(), Json::Num(v));
        };
        num("requests", self.requests as f64);
        num("batches", self.batches as f64);
        num("mean_batch_rows", self.mean_batch_rows);
        num("wall_secs", self.wall_secs);
        num("qps", self.qps);
        num("p50_ms", self.p50_ms);
        num("p90_ms", self.p90_ms);
        num("p99_ms", self.p99_ms);
        num("mean_ms", self.mean_ms);
        num("max_ms", self.max_ms);
        num("mismatches", self.mismatches as f64);
        num("barriers", self.barriers as f64);
        num("comm_instances", self.comm_instances as f64);
        num("comm_bytes", self.comm_bytes as f64);
        num("sim_predict_secs", self.sim_predict_secs);
        num("barriers_per_batch", self.barriers_per_batch);
        num("peak_slots_in_flight", self.peak_slots_in_flight as f64);
        Json::Obj(o)
    }

    /// Human-readable two-line summary.
    pub fn render(&self) -> String {
        format!(
            "served {} requests in {} micro-batches ({:.1} rows/batch) over {:.2}s — {:.0} qps\n\
             latency ms: p50 {:.2} p90 {:.2} p99 {:.2} mean {:.2} max {:.2} | mismatches {}\n\
             sim: {:.4}s predict, {} barriers ({:.2}/batch), {} comm instances, {} bytes, peak {} slots in flight\n",
            self.requests,
            self.batches,
            self.mean_batch_rows,
            self.wall_secs,
            self.qps,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.mean_ms,
            self.max_ms,
            self.mismatches,
            self.sim_predict_secs,
            self.barriers,
            self.barriers_per_batch,
            self.comm_instances,
            self.comm_bytes,
            self.peak_slots_in_flight,
        )
    }
}

/// Drive one closed-loop serving run: `cfg.clients` threads issuing
/// requests drawn from the rows of `pool` against `session`, with the
/// dispatcher micro-batching between them. When `expected` is given
/// (serial scores aligned with `pool`'s rows), every reply is checked
/// bit-identical.
pub fn run(
    session: &ServingSession,
    pool: &Mat,
    expected: Option<&[f32]>,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    anyhow::ensure!(cfg.clients >= 1, "need at least one client");
    anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
    anyhow::ensure!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
    anyhow::ensure!(pool.rows() > 0, "feature pool is empty");
    if let Some(exp) = expected {
        anyhow::ensure!(
            exp.len() == pool.rows(),
            "reference scores ({}) must align with the pool rows ({})",
            exp.len(),
            pool.rows()
        );
    }
    let queue = RequestQueue::new(cfg.queue_cap);
    let mismatches = AtomicU64::new(0);
    let pool_rows = pool.rows();
    let sim_before = session.sim();
    let t0 = Instant::now();
    let (dispatched, mut latencies) = std::thread::scope(|scope| {
        let dispatcher = {
            let queue = &queue;
            scope.spawn(move || dispatch_loop(session, pool, queue, cfg))
        };
        let mut seeder = Rng::new(cfg.seed);
        let clients: Vec<_> = (0..cfg.clients)
            .map(|c| {
                let rng = seeder.fork(c as u64);
                let queue = &queue;
                let mism = &mismatches;
                scope.spawn(move || client_loop(queue, cfg, rng, pool_rows, expected, mism))
            })
            .collect();
        let mut latencies = Vec::new();
        for h in clients {
            latencies.extend(h.join().expect("client thread panicked"));
        }
        queue.close();
        (dispatcher.join().expect("dispatcher panicked"), latencies)
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let (batches, rows) = dispatched?;
    let sim = session.sim();

    latencies.sort_by(f64::total_cmp);
    let requests = latencies.len() as u64;
    let mean_ms = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    debug_assert_eq!(rows, requests, "every answered request is one scored row");
    Ok(ServeReport {
        requests,
        batches,
        mean_batch_rows: if batches == 0 { 0.0 } else { rows as f64 / batches as f64 },
        wall_secs,
        qps: if wall_secs > 0.0 { requests as f64 / wall_secs } else { 0.0 },
        p50_ms: percentile(&latencies, 50.0),
        p90_ms: percentile(&latencies, 90.0),
        p99_ms: percentile(&latencies, 99.0),
        mean_ms,
        max_ms: latencies.last().copied().unwrap_or(0.0),
        mismatches: mismatches.load(Ordering::Relaxed),
        barriers: sim.barriers() - sim_before.barriers(),
        comm_instances: sim.comm_instances() - sim_before.comm_instances(),
        comm_bytes: sim.comm_bytes() - sim_before.comm_bytes(),
        sim_predict_secs: sim.step_secs(Step::Predict) - sim_before.step_secs(Step::Predict),
        barriers_per_batch: if batches == 0 {
            0.0
        } else {
            (sim.barriers() - sim_before.barriers()) as f64 / batches as f64
        },
        peak_slots_in_flight: session.peak_slots_in_flight(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{CostModel, Executor};
    use crate::config::settings::Loss;
    use crate::coordinator::TrainedModel;
    use crate::runtime::backend::NativeCompute;
    use std::sync::Arc;

    #[test]
    fn micro_batch_plan_covers_everything() {
        assert_eq!(plan_micro_batches(0, 8), Vec::<usize>::new());
        assert_eq!(plan_micro_batches(5, 8), vec![5]);
        assert_eq!(plan_micro_batches(8, 8), vec![8]);
        assert_eq!(plan_micro_batches(21, 8), vec![8, 8, 5]);
        assert_eq!(plan_micro_batches(21, 8).iter().sum::<usize>(), 21);
    }

    #[test]
    fn flush_policy() {
        let ms = Duration::from_millis;
        // Full batch flushes regardless of age.
        assert!(flush_due(8, ms(0), 8, ms(5), false));
        // Partial batch waits until the delay bound…
        assert!(!flush_due(3, ms(1), 8, ms(5), false));
        assert!(flush_due(3, ms(5), 8, ms(5), false));
        // …or the queue is closing.
        assert!(flush_due(1, ms(0), 8, ms(5), true));
        // Nothing waiting → nothing to flush.
        assert!(!flush_due(0, ms(9), 8, ms(5), false));
    }

    #[test]
    fn percentiles_on_small_samples() {
        assert_eq!(percentile(&[], 99.0), 0.0);
        let one = [7.0];
        assert_eq!(percentile(&one, 50.0), 7.0);
        assert_eq!(percentile(&one, 99.0), 7.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn closed_loop_smoke_is_bit_identical() {
        let mut rng = Rng::new(3);
        let (m, d) = (40, 5);
        let model = TrainedModel {
            basis: Mat::from_fn(m, d, |_, _| rng.normal_f32()),
            beta: (0..m).map(|_| 0.05 * rng.normal_f32()).collect(),
            gamma: 0.25,
            loss: Loss::SqHinge,
        };
        let backend = Arc::new(NativeCompute::new());
        let pool = Mat::from_fn(16, d, |_, _| rng.normal_f32());
        let expected = model.predict(backend.as_ref(), &pool).unwrap();
        let session =
            ServingSession::load(&model, backend, 2, Executor::serial(), CostModel::free())
                .unwrap();
        let cfg = ServeConfig {
            clients: 3,
            requests_per_client: 5,
            mean_think_ms: 0.0,
            max_batch: 4,
            max_delay_ms: 1.0,
            slots: 2,
            queue_cap: 8,
            seed: 9,
        };
        let report = run(&session, &pool, Some(&expected), &cfg).unwrap();
        assert_eq!(report.requests, 15);
        assert_eq!(report.mismatches, 0);
        assert!(report.batches >= 1);
        // One barrier per dispatch, never more than one per micro-batch.
        assert!(report.barriers <= report.batches);
        assert!(report.barriers_per_batch <= 1.0 + 1e-12);
        assert!(report.qps > 0.0);
        assert!(report.p99_ms >= report.p50_ms);
        // Render + JSON shapes hold together.
        assert!(report.render().contains("qps"));
        let json = format!("{}", report.to_json());
        assert!(json.contains("\"p99_ms\""), "{json}");
    }
}
