"""AOT emission: every module lowers to parseable HLO text + sane manifest."""

import json
import os
import tempfile

import pytest

from compile import aot


def test_module_specs_cover_contract():
    names = {name for name, _, _ in aot.module_specs()}
    # one kernel/kmeans/predict module per feature width
    for d in aot.DS:
        assert f"kernel_block_d{d}" in names
        assert f"kmeans_assign_d{d}" in names
        assert f"predict_block_d{d}" in names
    # loss family complete
    for loss in aot.LOSSES:
        assert f"loss_{loss}" in names
        assert f"fgrad_{loss}" in names
    assert {"matvec", "matvec_t", "hd_tile", "mask_mul"} <= names


@pytest.mark.parametrize(
    "name",
    ["kernel_block_d32", "matvec", "matvec_t", "fgrad_sqhinge", "kmeans_assign_d32"],
)
def test_lowering_emits_hlo_text(name):
    spec = {n: (f, a) for n, f, a in aot.module_specs()}[name]
    text, inputs, outputs = aot.lower_one(name, *spec)
    assert text.startswith("HloModule"), text[:60]
    assert "ROOT" in text
    assert len(inputs) >= 1 and len(outputs) >= 1


def test_end_to_end_emission_writes_manifest():
    with tempfile.TemporaryDirectory() as tmp:
        import sys
        from unittest import mock

        argv = ["aot", "--out", tmp, "--only", "matvec,loss_sqhinge"]
        with mock.patch.object(sys, "argv", argv):
            aot.main()
        with open(os.path.join(tmp, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["tb"] == aot.TB and manifest["tm"] == aot.TM
        names = {m["name"] for m in manifest["modules"]}
        assert names == {"matvec", "loss_sqhinge"}
        for mod in manifest["modules"]:
            path = os.path.join(tmp, mod["file"])
            assert os.path.exists(path)
            with open(path) as f:
                assert f.read(9) == "HloModule"


def test_manifest_shapes_match_tile_grid():
    spec = {n: (f, a) for n, f, a in aot.module_specs()}
    _, inputs, outputs = aot.lower_one("kernel_block_d64", *spec["kernel_block_d64"])
    assert inputs[0]["shape"] == [aot.TB, 64]
    assert inputs[1]["shape"] == [aot.TM, 64]
    assert outputs[0]["shape"] == [aot.TB, aot.TM]
    _, inputs, outputs = aot.lower_one("fgrad_logistic", *spec["fgrad_logistic"])
    assert [o["shape"] for o in outputs] == [[], [aot.TM], [aot.TB]]
