"""L1 Pallas kernels vs pure-jnp oracles -- the CORE correctness signal.

Every kernel is checked with assert_allclose against ref.py on fixed seeds,
plus hypothesis sweeps over shapes, scales and gamma.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linops, rbf, ref

RTOL = 2e-5
ATOL = 2e-6


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# --------------------------------------------------------------------------
# RBF / dist2 tiles
# --------------------------------------------------------------------------
@pytest.mark.parametrize("d", [32, 64, 128, 256])
def test_rbf_block_matches_ref(d):
    x = _rand(0, (256, d))
    z = _rand(1, (256, d))
    gamma = jnp.array([0.37], jnp.float32)
    got = rbf.rbf_block(x, z, gamma)
    want = ref.rbf_block(x, z, gamma)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("d", [32, 128])
def test_dist2_block_matches_ref(d):
    x = _rand(2, (256, d))
    z = _rand(3, (256, d))
    got = rbf.dist2_block(x, z)
    want = ref.dist2_block(x, z)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=RTOL, atol=1e-4)


def test_rbf_identical_points_give_one():
    x = _rand(4, (128, 32))
    gamma = jnp.array([1.3], jnp.float32)
    k = rbf.rbf_block(x, x, gamma)
    np.testing.assert_allclose(np.array(jnp.diag(k)), 1.0, rtol=1e-5, atol=1e-5)


def test_rbf_zero_feature_padding_is_exact():
    """Zero-padding features must not change kernel values (runtime contract)."""
    x = _rand(5, (128, 32))
    z = _rand(6, (128, 32))
    gamma = jnp.array([0.21], jnp.float32)
    xp = jnp.pad(x, ((0, 0), (0, 32)))
    zp = jnp.pad(z, ((0, 0), (0, 32)))
    np.testing.assert_allclose(
        np.array(rbf.rbf_block(xp, zp, gamma)),
        np.array(rbf.rbf_block(x, z, gamma)),
        rtol=RTOL,
        atol=ATOL,
    )


def test_rbf_values_in_unit_interval():
    x = _rand(7, (128, 64), scale=5.0)
    z = _rand(8, (128, 64), scale=5.0)
    k = np.array(rbf.rbf_block(x, z, jnp.array([0.9], jnp.float32)))
    assert k.min() >= 0.0 and k.max() <= 1.0 + 1e-6


@settings(max_examples=20, deadline=None)
@given(
    bb=st.sampled_from([128, 256]),
    bm=st.sampled_from([128, 256]),
    d=st.sampled_from([32, 64, 128]),
    gamma=st.floats(1e-3, 10.0),
    scale=st.floats(0.1, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_block_hypothesis(bb, bm, d, gamma, scale, seed):
    x = _rand(seed, (bb, d), scale)
    z = _rand(seed + 1, (bm, d), scale)
    g = jnp.array([gamma], jnp.float32)
    got = rbf.rbf_block(x, z, g)
    want = ref.rbf_block(x, z, g)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# matvec / matvec_t tiles
# --------------------------------------------------------------------------
def test_matvec_matches_ref():
    c = _rand(10, (256, 256))
    v = _rand(11, (256,))
    np.testing.assert_allclose(
        np.array(linops.matvec(c, v)), np.array(ref.matvec(c, v)), rtol=RTOL, atol=1e-4
    )


def test_matvec_t_matches_ref():
    c = _rand(12, (256, 256))
    r = _rand(13, (256,))
    np.testing.assert_allclose(
        np.array(linops.matvec_t(c, r)),
        np.array(ref.matvec_t(c, r)),
        rtol=RTOL,
        atol=1e-4,
    )


@settings(max_examples=20, deadline=None)
@given(
    tb=st.sampled_from([128, 256, 512]),
    tm=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_roundtrip_hypothesis(tb, tm, seed):
    """<C v, r> == <v, C^T r> (adjoint identity ties both kernels together)."""
    c = _rand(seed, (tb, tm))
    v = _rand(seed + 1, (tm,))
    r = _rand(seed + 2, (tb,))
    lhs = float(jnp.dot(linops.matvec(c, v), r))
    rhs = float(jnp.dot(v, linops.matvec_t(c, r)))
    assert abs(lhs - rhs) <= 1e-2 * max(1.0, abs(lhs))


def test_matvec_zero_vector():
    c = _rand(14, (256, 256))
    out = np.array(linops.matvec(c, jnp.zeros((256,), jnp.float32)))
    assert np.all(out == 0.0)
