"""L2 model graphs: loss stages, fused fgrad/hd tiles, kmeans, prediction.

Checks loss stages against jax.grad/Gauss-Newton semantics and the fused
modules against their unfused composition.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _labels(key, n):
    bits = jax.random.bernoulli(jax.random.PRNGKey(key), 0.5, (n,))
    return jnp.where(bits, 1.0, -1.0).astype(jnp.float32)


LOSS_NAMES = ["sqhinge", "logistic", "squared"]


# --------------------------------------------------------------------------
# Loss stages: value/resid/dcoef consistency with autodiff.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", LOSS_NAMES)
def test_loss_resid_is_autodiff_gradient(name):
    o = _rand(0, (256,), 2.0)
    y = _labels(1, 256)
    mask = jnp.ones((256,), jnp.float32)
    stage = model.loss_stage(name)
    loss, resid, dcoef = stage(o, y, mask)

    def scalar_loss(o_):
        return stage(o_, y, mask)[0]

    g = jax.grad(scalar_loss)(o)
    np.testing.assert_allclose(np.array(resid), np.array(g), rtol=1e-4, atol=1e-5)
    assert np.all(np.array(dcoef) >= 0.0)


@pytest.mark.parametrize("name", LOSS_NAMES)
def test_loss_mask_zeroes_padding(name):
    o = _rand(2, (256,), 2.0)
    y = _labels(3, 256)
    mask = jnp.concatenate([jnp.ones((100,)), jnp.zeros((156,))]).astype(jnp.float32)
    stage = model.loss_stage(name)
    loss_m, resid_m, dcoef_m = stage(o, y, mask)
    loss_t, resid_t, _ = stage(o[:100], y[:100], jnp.ones((100,), jnp.float32))
    np.testing.assert_allclose(float(loss_m), float(loss_t), rtol=1e-5)
    assert np.all(np.array(resid_m)[100:] == 0.0)
    assert np.all(np.array(dcoef_m)[100:] == 0.0)
    np.testing.assert_allclose(
        np.array(resid_m)[:100], np.array(resid_t), rtol=1e-5, atol=1e-6
    )


def test_sqhinge_matches_paper_definition():
    """D_ii = 1 iff 1 - y_i o_i > 0; resid = D (o - y) (paper section 3)."""
    o = jnp.array([2.0, 0.5, -2.0, -0.5], jnp.float32)
    y = jnp.array([1.0, 1.0, -1.0, -1.0], jnp.float32)
    mask = jnp.ones((4,), jnp.float32)
    loss, resid, dcoef = model.loss_stage("sqhinge")(o, y, mask)
    # margins: 1-2=-1 (off), 1-0.5=0.5 (on), 1-2=-1 (off), 1-0.5=0.5 (on)
    np.testing.assert_allclose(np.array(dcoef), [0.0, 1.0, 0.0, 1.0])
    np.testing.assert_allclose(np.array(resid), [0.0, -0.5, 0.0, 0.5])
    np.testing.assert_allclose(float(loss), 0.5 * (0.25 + 0.25), rtol=1e-6)


# --------------------------------------------------------------------------
# Fused tiles == unfused composition.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", LOSS_NAMES)
def test_fgrad_tile_matches_composition(name):
    c = _rand(4, (256, 256))
    beta = _rand(5, (256,), 0.1)
    y = _labels(6, 256)
    mask = jnp.ones((256,), jnp.float32)
    loss_f, grad_f, dcoef_f = model.fgrad_tile(name)(c, beta, y, mask)
    o = c @ beta
    loss_u, resid_u, dcoef_u = model.loss_stage(name)(o, y, mask)
    np.testing.assert_allclose(float(loss_f), float(loss_u), rtol=1e-4)
    np.testing.assert_allclose(
        np.array(grad_f), np.array(c.T @ resid_u), rtol=1e-3, atol=1e-3
    )
    np.testing.assert_allclose(np.array(dcoef_f), np.array(dcoef_u), atol=1e-6)


def test_hd_tile_matches_composition():
    c = _rand(7, (256, 256))
    d = _rand(8, (256,), 0.3)
    dcoef = jnp.abs(_rand(9, (256,))) > 0.5
    dcoef = dcoef.astype(jnp.float32)
    (got,) = model.hd_tile(c, d, dcoef)
    want = c.T @ (dcoef * (c @ d))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), name=st.sampled_from(LOSS_NAMES))
def test_hd_is_gauss_newton_quadratic_form(seed, name):
    """d^T (C^T D C) d >= 0: the loss Hessian term is PSD for every loss."""
    c = _rand(seed, (128, 128))
    beta = _rand(seed + 1, (128,), 0.2)
    y = _labels(seed + 2, 128)
    mask = jnp.ones((128,), jnp.float32)
    _, _, dcoef = model.fgrad_tile(name)(c, beta, y, mask)
    d = _rand(seed + 3, (128,))
    quad = float(jnp.dot(d, jnp.asarray(model.hd_tile(c, d, dcoef)[0])))
    assert quad >= -1e-3


# --------------------------------------------------------------------------
# K-means assignment.
# --------------------------------------------------------------------------
def test_kmeans_assign_matches_ref():
    x = _rand(10, (256, 64))
    cent = _rand(11, (256, 64))
    cmask = jnp.concatenate([jnp.ones((40,)), jnp.zeros((216,))]).astype(jnp.float32)
    rmask = jnp.ones((256,), jnp.float32)
    idx, counts, sums, inertia = model.kmeans_assign(x, cent, cmask, rmask)
    idx_r, counts_r, sums_r, inertia_r = ref.kmeans_assign(x, cent, cmask, rmask)
    np.testing.assert_array_equal(np.array(idx), np.array(idx_r))
    np.testing.assert_allclose(np.array(counts), np.array(counts_r))
    np.testing.assert_allclose(np.array(sums), np.array(sums_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(inertia), float(inertia_r), rtol=1e-4)


def test_kmeans_assign_never_picks_dead_centroid():
    x = _rand(12, (256, 32), 3.0)
    cent = _rand(13, (256, 32), 3.0)
    live = 17
    cmask = jnp.concatenate([jnp.ones((live,)), jnp.zeros((256 - live,))]).astype(
        jnp.float32
    )
    rmask = jnp.ones((256,), jnp.float32)
    idx, counts, _, _ = model.kmeans_assign(x, cent, cmask, rmask)
    assert int(np.array(idx).max()) < live
    assert float(np.array(counts)[live:].sum()) == 0.0
    assert float(np.array(counts).sum()) == 256.0


def test_kmeans_counts_sums_consistent():
    x = _rand(14, (256, 32))
    cent = _rand(15, (256, 32))
    cmask = jnp.ones((256,), jnp.float32)
    rmask = jnp.ones((256,), jnp.float32)
    idx, counts, sums, _ = model.kmeans_assign(x, cent, cmask, rmask)
    np.testing.assert_allclose(
        np.array(sums).sum(axis=0), np.array(x).sum(axis=0), rtol=1e-3, atol=1e-3
    )
    assert float(np.array(counts).sum()) == 256.0


def test_kmeans_row_mask_excludes_padding():
    x = _rand(20, (256, 32))
    cent = _rand(21, (256, 32))
    cmask = jnp.ones((256,), jnp.float32)
    live = 100
    rmask = jnp.concatenate([jnp.ones((live,)), jnp.zeros((256 - live,))]).astype(
        jnp.float32
    )
    _, counts, sums, inertia = model.kmeans_assign(x, cent, cmask, rmask)
    assert float(np.array(counts).sum()) == float(live)
    np.testing.assert_allclose(
        np.array(sums).sum(axis=0),
        np.array(x)[:live].sum(axis=0),
        rtol=1e-3,
        atol=1e-3,
    )
    # inertia only over live rows
    _, _, _, inertia_full = model.kmeans_assign(
        x, cent, cmask, jnp.ones((256,), jnp.float32)
    )
    assert float(inertia) < float(inertia_full)


# --------------------------------------------------------------------------
# Prediction tile.
# --------------------------------------------------------------------------
def test_predict_block_matches_ref():
    x = _rand(16, (256, 64))
    z = _rand(17, (256, 64))
    beta = _rand(18, (256,), 0.1)
    gamma = jnp.array([0.4], jnp.float32)
    (got,) = model.predict_block(x, z, gamma, beta)
    want = ref.rbf_block(x, z, gamma) @ beta
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)
