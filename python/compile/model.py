"""L2: the per-node compute graphs of Algorithm 1, built on the L1 kernels.

Each public function here is one AOT module: `aot.py` lowers it (for the
tile-shape grid in `aot.SHAPES`) to HLO text that the Rust runtime loads via
PJRT and calls on the training hot path. Python never runs at training time.

Functions return TUPLES (even singletons) because the lowering pipeline uses
return_tuple=True and the Rust side unwraps with to_tuple1/2/3.

Conventions shared with rust/src/runtime:
  * all floats are f32; kmeans assignment indices are i32;
  * `mask` vectors carry 1.0 for real rows and 0.0 for padding, so padded
    tiles contribute exactly zero to losses, gradients and AllReduce sums;
  * gamma = 1 / (2 sigma^2) arrives as a (1,) f32 array.
"""

import jax.numpy as jnp

from .kernels import linops, rbf


# --------------------------------------------------------------------------
# Step 3: kernel-matrix row block (the compute hot spot; L1 Pallas inside).
# --------------------------------------------------------------------------
def kernel_block(x, z, gamma):
    """C tile: (tb, d) x (tm, d) -> (tb, tm) Gaussian kernel values."""
    return (rbf.rbf_block(x, z, gamma),)


def dist2_block(x, z):
    """Squared-distance tile (K-means seeding / diagnostics)."""
    return (rbf.dist2_block(x, z),)


# --------------------------------------------------------------------------
# Step 4 (TRON): block matrix-vector products + loss stages.
# --------------------------------------------------------------------------
def matvec(c, v):
    """o tile: (tb, tm) @ (tm,) -> (tb,). One summand of o = C beta."""
    return (linops.matvec(c, v),)


def matvec_t(c, r):
    """grad tile: (tb, tm)^T @ (tb,) -> (tm,). One summand of C^T resid."""
    return (linops.matvec_t(c, r),)


def _loss_sqhinge(o, y, mask):
    margin = 1.0 - y * o
    active = jnp.where((margin > 0) & (mask > 0), 1.0, 0.0)
    loss = 0.5 * jnp.sum(active * margin * margin)
    resid = active * (o - y)
    return loss, resid, active


def _loss_logistic(o, y, mask):
    m = y * o
    loss = jnp.sum(mask * jnp.logaddexp(0.0, -m))
    sig = 1.0 / (1.0 + jnp.exp(m))
    resid = mask * (-y * sig)
    dcoef = mask * sig * (1.0 - sig)
    return loss, resid, dcoef


def _loss_squared(o, y, mask):
    r = mask * (o - y)
    loss = 0.5 * jnp.sum(r * r)
    return loss, r, mask


LOSSES = {
    "sqhinge": _loss_sqhinge,
    "logistic": _loss_logistic,
    "squared": _loss_squared,
}


def loss_stage(name):
    """(o, y, mask) -> (loss_sum, resid, dcoef) for the named loss."""
    fn = LOSSES[name]

    def stage(o, y, mask):
        return fn(o, y, mask)

    stage.__name__ = f"loss_{name}"
    return stage


def fgrad_tile(name):
    """Fused f/grad for one row tile when m fits a single basis tile.

    (c, beta, y, mask) -> (loss_sum, grad, dcoef). Saves two PJRT dispatches
    per row tile versus matvec + loss_stage + matvec_t when m <= TM.
    """
    fn = LOSSES[name]

    def stage(c, beta, y, mask):
        o = linops.matvec(c, beta)
        loss, resid, dcoef = fn(o, y, mask)
        grad = linops.matvec_t(c, resid)
        return loss, grad, dcoef

    stage.__name__ = f"fgrad_{name}"
    return stage


def hd_tile(c, d, dcoef):
    """Fused Hd loss term for one row tile when m fits a single basis tile.

    (c, d, dcoef) -> (C^T (D (C d)),). D is the cached Gauss-Newton diagonal
    from the last f/grad evaluation at the current beta.
    """
    z = linops.matvec(c, d)
    return (linops.matvec_t(c, dcoef * z),)


def mask_mul(z, dcoef):
    """(tb,), (tb,) -> elementwise product (the D z step of 4c)."""
    return (z * dcoef,)


# --------------------------------------------------------------------------
# Basis selection: distributed K-means assignment step.
# --------------------------------------------------------------------------
def kmeans_assign(x, cent, cmask, rmask):
    """(idx, counts, sums, inertia) for one row tile against all centroids.

    Distances run through the L1 dist2 tile; the one-hot contraction that
    builds per-centroid sums is another MXU-shaped matmul. `cmask` marks
    live centroids (dead ones pushed to +inf distance); `rmask` marks live
    rows (padding rows contribute nothing to counts/sums/inertia).
    """
    d2 = rbf.dist2_block(x, cent)
    d2 = d2 + (1.0 - cmask)[None, :] * 1e30
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    onehot = (idx[:, None] == jnp.arange(cent.shape[0])[None, :]).astype(
        jnp.float32
    ) * rmask[:, None]
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ x
    inertia = jnp.sum(jnp.min(d2, axis=1) * rmask)
    return idx, counts, sums, inertia


# --------------------------------------------------------------------------
# Prediction: o tile for test rows = kernel_block + matvec fused.
# --------------------------------------------------------------------------
def predict_block(x, z, gamma, beta):
    """(tb, d) test rows -> (tb,) decision values C(x, Z) beta."""
    c = rbf.rbf_block(x, z, gamma)
    return (linops.matvec(c, beta),)
