"""AOT lowering: every L2 module -> HLO text + manifest.json in artifacts/.

This is the single build-time Python entry point (`make artifacts`). After it
runs, the Rust binary is self-contained: it loads the HLO text via
`xla::HloModuleProto::from_text_file`, compiles on the PJRT CPU client and
executes on the training hot path.

Interchange format is HLO **text**, NOT `lowered.compile().serialize()` /
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate
binds) rejects with `proto.id() <= INT_MAX`. The text parser reassigns ids,
so text round-trips cleanly. See /opt/xla-example/README.md.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# --------------------------------------------------------------------------
# Tile-shape grid (shared contract with rust/src/runtime/tiles.rs).
#
# HLO modules have static shapes: the Rust runtime zero-pads each dataset to
# this grid and loops tiles. TB/TM are the row/basis tile edges; D is the
# padded feature width (zero feature padding is exact for the RBF kernel:
# padded coordinates contribute 0 to ||x - z||^2).
# --------------------------------------------------------------------------
TB = 256
TM = 256
DS = [32, 64, 128, 256, 512, 1024]
LOSSES = list(model.LOSSES)

F32 = jnp.float32


def _s(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def module_specs():
    """(name, fn, example_args) for every AOT module."""
    specs = []
    for d in DS:
        specs.append(
            (f"kernel_block_d{d}", model.kernel_block, [_s(TB, d), _s(TM, d), _s(1)])
        )
        specs.append((f"dist2_block_d{d}", model.dist2_block, [_s(TB, d), _s(TM, d)]))
        specs.append(
            (
                f"kmeans_assign_d{d}",
                model.kmeans_assign,
                [_s(TB, d), _s(TM, d), _s(TM), _s(TB)],
            )
        )
        specs.append(
            (
                f"predict_block_d{d}",
                model.predict_block,
                [_s(TB, d), _s(TM, d), _s(1), _s(TM)],
            )
        )
    specs.append(("matvec", model.matvec, [_s(TB, TM), _s(TM)]))
    specs.append(("matvec_t", model.matvec_t, [_s(TB, TM), _s(TB)]))
    specs.append(("hd_tile", model.hd_tile, [_s(TB, TM), _s(TM), _s(TB)]))
    specs.append(("mask_mul", model.mask_mul, [_s(TB), _s(TB)]))
    for name in LOSSES:
        specs.append((f"loss_{name}", model.loss_stage(name), [_s(TB), _s(TB), _s(TB)]))
        specs.append(
            (
                f"fgrad_{name}",
                model.fgrad_tile(name),
                [_s(TB, TM), _s(TM), _s(TB), _s(TB)],
            )
        )
    return specs


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(sds):
    return {"float32": "f32", "int32": "i32"}[str(sds.dtype)]


def lower_one(name, fn, args):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    out_tree = jax.eval_shape(fn, *args)
    outputs = [
        {"shape": list(o.shape), "dtype": _dtype_tag(o)}
        for o in jax.tree_util.tree_leaves(out_tree)
    ]
    inputs = [{"shape": list(a.shape), "dtype": _dtype_tag(a)} for a in args]
    return text, inputs, outputs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated module-name filter (debug)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {
        "version": 1,
        "tb": TB,
        "tm": TM,
        "ds": DS,
        "losses": LOSSES,
        "modules": [],
    }
    for name, fn, eargs in module_specs():
        if only and name not in only:
            continue
        text, inputs, outputs = lower_one(name, fn, eargs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["modules"].append(
            {
                "name": name,
                "file": fname,
                "sha256_16": digest,
                "inputs": inputs,
                "outputs": outputs,
            }
        )
        print(f"  lowered {name:24s} -> {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['modules'])} modules + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
