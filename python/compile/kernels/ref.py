"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These are the reference semantics the pytest suite (and hypothesis sweeps)
check the Pallas kernels against with assert_allclose. Keep them naive and
obviously correct -- no tiling, no tricks.
"""

import jax.numpy as jnp


def rbf_block(x, z, gamma):
    """exp(-gamma * ||x_i - z_k||^2), computed from explicit differences."""
    diff = x[:, None, :] - z[None, :, :]  # (tb, tm, d)
    d2 = jnp.sum(diff * diff, axis=2)
    return jnp.exp(-gamma[0] * d2)


def dist2_block(x, z):
    diff = x[:, None, :] - z[None, :, :]
    return jnp.sum(diff * diff, axis=2)


def matvec(c, v):
    return c @ v


def matvec_t(c, r):
    return c.T @ r


def loss_sqhinge(o, y, mask):
    """Squared hinge: l = 0.5 * max(1 - y o, 0)^2 summed over valid rows.

    Returns (loss_sum, resid, dcoef) with resid = dl/do = D (o - y) and
    dcoef the Gauss-Newton diagonal D_ii (1 if 1 - y_i o_i > 0 else 0).
    """
    margin = 1.0 - y * o
    active = jnp.where((margin > 0) & (mask > 0), 1.0, 0.0)
    loss = 0.5 * jnp.sum(active * margin * margin)
    resid = active * (o - y)
    return loss, resid, active


def loss_logistic(o, y, mask):
    """Logistic loss (kernel logistic regression): l = log(1 + exp(-y o)).

    resid = dl/do = -y * sigma(-y o); dcoef = d2l/do2 = sigma (1 - sigma).
    """
    m = y * o
    loss = jnp.sum(mask * jnp.logaddexp(0.0, -m))
    sig = 1.0 / (1.0 + jnp.exp(m))  # sigma(-y o)
    resid = mask * (-y * sig)
    dcoef = mask * sig * (1.0 - sig)
    return loss, resid, dcoef


def loss_squared(o, y, mask):
    """Squared loss (kernel ridge regression): l = 0.5 (o - y)^2."""
    r = mask * (o - y)
    loss = 0.5 * jnp.sum(r * r)
    return loss, r, mask


def kmeans_assign(x, cent, cmask, rmask):
    """Nearest valid centroid per row; returns (idx, counts, sums, inertia).

    cmask is (tm,) with 1.0 for live centroids; dead (padding) centroids are
    pushed to +inf distance. rmask is (tb,) with 1.0 for live rows; padding
    rows contribute nothing. counts/sums are the per-centroid accumulators a
    node contributes to the centroid-update AllReduce.
    """
    d2 = dist2_block(x, cent)
    d2 = d2 + (1.0 - cmask)[None, :] * 1e30
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    onehot = (idx[:, None] == jnp.arange(cent.shape[0])[None, :]).astype(
        jnp.float32
    ) * rmask[:, None]
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ x
    inertia = jnp.sum(jnp.min(d2, axis=1) * rmask)
    return idx, counts, sums, inertia


def fgrad_tile(c, beta, y, mask, loss_fn):
    """Fused per-row-tile f/grad when m fits one basis tile.

    Returns (loss_sum, grad) with grad = C^T resid (the loss part of the
    gradient row block; the lambda W beta part is assembled by the caller).
    """
    o = c @ beta
    loss, resid, _ = loss_fn(o, y, mask)
    return loss, c.T @ resid
