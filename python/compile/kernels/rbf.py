"""L1 Pallas kernels: Gaussian (RBF) kernel-matrix tile and squared-distance tile.

The paper's compute hot spot is step 3 of Algorithm 1: each node computes its
row block of the kernel matrix C, C_ik = k(x_i, xbar_k), with the Gaussian
kernel k(x, z) = exp(-||x - z||^2 / (2 sigma^2)) = exp(-gamma ||x - z||^2).

Hardware adaptation (paper targeted commodity Hadoop CPUs; we re-think the
block computation for the TPU model Pallas exposes):

  * ||x - z||^2 is decomposed as ||x||^2 + ||z||^2 - 2 x.z so the dominant
    cost is a (bb x D) @ (D x bm) matmul that maps onto the MXU systolic
    array, instead of a pairwise-distance loop.
  * BlockSpecs tile X into (bb, D) and Z into (bm, D) VMEM-resident blocks;
    the (bb, bm) output tile stays in VMEM across the exp epilogue, i.e. the
    HBM<->VMEM schedule a CUDA kernel would express with threadblocks +
    shared memory is expressed with the grid + index maps.
  * Row/column norms are computed inside the kernel from the already-resident
    operand tiles (fused), so the exp epilogue is elementwise over the matmul
    accumulator -- there is no second pass over HBM.

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers the kernel to plain HLO that the Rust
runtime's CPU client runs at native (XLA-compiled) speed. Real-TPU efficiency
is estimated from VMEM footprint + MXU-shape arithmetic in DESIGN.md.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sub-tile (VMEM block) edge. 128 matches the MXU systolic array edge and the
# lane width of the VPU, so matmul tiles are MXU-aligned.
BLOCK = 128


def _rbf_tile_kernel(gamma_ref, x_ref, z_ref, o_ref):
    """One (bb, bm) output block: exp(-gamma * ||x_i - z_k||^2)."""
    x = x_ref[...]  # (bb, D) f32, VMEM
    z = z_ref[...]  # (bm, D) f32, VMEM
    gamma = gamma_ref[0]
    # Fused row/col norms over the resident tiles.
    xsq = jnp.sum(x * x, axis=1, keepdims=True)  # (bb, 1)
    zsq = jnp.sum(z * z, axis=1, keepdims=True).T  # (1, bm)
    # MXU-shaped contraction: (bb, D) x (bm, D) -> (bb, bm), f32 accumulate.
    dot = jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    # max(., 0): guards the tiny negative residuals of the factored form so
    # exp never sees a positive exponent.
    d2 = jnp.maximum(xsq + zsq - 2.0 * dot, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


def _dist2_tile_kernel(x_ref, z_ref, o_ref):
    """One (bb, bm) block of squared distances ||x_i - z_k||^2 (for K-means)."""
    x = x_ref[...]
    z = z_ref[...]
    xsq = jnp.sum(x * x, axis=1, keepdims=True)
    zsq = jnp.sum(z * z, axis=1, keepdims=True).T
    dot = jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = jnp.maximum(xsq + zsq - 2.0 * dot, 0.0)


def _grid_specs(tb, tm, d, block_b, block_m):
    grid = (tb // block_b, tm // block_m)
    x_spec = pl.BlockSpec((block_b, d), lambda i, j: (i, 0))
    z_spec = pl.BlockSpec((block_m, d), lambda i, j: (j, 0))
    o_spec = pl.BlockSpec((block_b, block_m), lambda i, j: (i, j))
    return grid, x_spec, z_spec, o_spec


def rbf_block(x, z, gamma, *, block_b=BLOCK, block_m=BLOCK):
    """C tile: (tb, d) x (tm, d) -> (tb, tm) Gaussian kernel values.

    gamma is a (1,) f32 array holding 1 / (2 sigma^2).
    """
    tb, d = x.shape
    tm, d2 = z.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert tb % block_b == 0 and tm % block_m == 0
    grid, x_spec, z_spec, o_spec = _grid_specs(tb, tm, d, block_b, block_m)
    gamma_spec = pl.BlockSpec((1,), lambda i, j: (0,))
    return pl.pallas_call(
        _rbf_tile_kernel,
        grid=grid,
        in_specs=[gamma_spec, x_spec, z_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((tb, tm), jnp.float32),
        interpret=True,
    )(gamma, x, z)


def dist2_block(x, z, *, block_b=BLOCK, block_m=BLOCK):
    """Squared-distance tile: (tb, d) x (tm, d) -> (tb, tm)."""
    tb, d = x.shape
    tm, d2 = z.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert tb % block_b == 0 and tm % block_m == 0
    grid, x_spec, z_spec, o_spec = _grid_specs(tb, tm, d, block_b, block_m)
    return pl.pallas_call(
        _dist2_tile_kernel,
        grid=grid,
        in_specs=[x_spec, z_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((tb, tm), jnp.float32),
        interpret=True,
    )(x, z)


def vmem_bytes(block_b, block_m, d):
    """Estimated VMEM residency of one grid step (f32)."""
    return 4 * (block_b * d + block_m * d + block_b * block_m)
