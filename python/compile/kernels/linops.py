"""L1 Pallas kernels: the block matrix-vector products of Algorithm 1 step 4.

TRON's distributed part "consists of only matrix-vector products" (paper
section 1): o = C beta per row block, and grad pieces C^T (D (C beta - y)).
These are the per-node compute of steps 4a-4c.

matvec keeps the full operand row-panel in VMEM and contracts against the
vector; matvec_t runs the transposed contraction block-column-wise. Both are
interpret=True for the same reason as rbf.py (CPU PJRT cannot run Mosaic).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf import BLOCK


def _matvec_kernel(c_ref, v_ref, o_ref):
    c = c_ref[...]  # (block_b, tm)
    v = v_ref[...]  # (tm,)
    o_ref[...] = jax.lax.dot_general(
        c, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _matvec_t_kernel(c_ref, r_ref, o_ref):
    c = c_ref[...]  # (tb, block_m)
    r = r_ref[...]  # (tb,)
    o_ref[...] = jax.lax.dot_general(
        c, r, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def matvec(c, v, *, block_b=BLOCK):
    """(tb, tm) @ (tm,) -> (tb,), row-panel grid."""
    tb, tm = c.shape
    assert v.shape == (tm,)
    assert tb % block_b == 0
    return pl.pallas_call(
        _matvec_kernel,
        grid=(tb // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, tm), lambda i: (i, 0)),
            pl.BlockSpec((tm,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tb,), jnp.float32),
        interpret=True,
    )(c, v)


def matvec_t(c, r, *, block_m=BLOCK):
    """(tb, tm)^T @ (tb,) -> (tm,), column-panel grid."""
    tb, tm = c.shape
    assert r.shape == (tb,)
    assert tm % block_m == 0
    return pl.pallas_call(
        _matvec_t_kernel,
        grid=(tm // block_m,),
        in_specs=[
            pl.BlockSpec((tb, block_m), lambda j: (0, j)),
            pl.BlockSpec((tb,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((tm,), jnp.float32),
        interpret=True,
    )(c, r)
